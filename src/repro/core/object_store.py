"""Versioned pytree object store over PMem pools (the paper's §V-C).

Objects are named, versioned pytrees of numpy/jax arrays. Every leaf is a
byte range in a pool region (byte-addressable: readers can map any slice of
any leaf without deserialization — this is what enables elastic checkpoint
resharding). A JSON manifest (committed atomically) indexes leaves with
shape/dtype/offset/crc. The store doubles as the node-local "filesystem on
B-APM" of §V-D; ``DistributedStore`` unions per-node stores into the
cross-node view.

The data plane moves objects through three zero-copy primitives rather
than tree materialization (ROADMAP item 4):

  ``copy_object``    pmem -> pmem raw path: streams the backing region in
                     bounded chunks via ``PMemRegion.read``/``write``
                     (every chunk flushed BEFORE the manifest commit
                     point) and commits the *source manifest verbatim* —
                     leaf CRCs are reused for streaming verification, no
                     tree is built, no CRC is recomputed over decoded
                     leaves. Optionally encodes with the delta-int8 wire
                     codec (``wire_codec.py``) at the source.
  ``export_object``  pmem -> wire payload for the external (drain)
                     boundary: bytes + manifest in one self-describing
                     dict, serialized exactly once by the external store.
  ``import_object``  wire payload -> pmem (stage-in / rehydration):
                     writes leaf bytes at manifest offsets and commits
                     the carried manifest; encoded payloads are stored
                     encoded and decoded on demand by readers.

Concurrency: all three verify streamed bytes against the manifest CRCs
they commit, so a source overwritten mid-copy (checkpoint slot reuse
racing a queued transfer) raises ``SupersededError`` instead of
committing a replica whose tag disagrees with its bytes.
"""
from __future__ import annotations

import itertools
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.annotations import rehydration_entry
from repro.core.pmem import PMemPool
from repro.core.wire_codec import (codec_meta, decode_leaf,
                                   decode_leaf_tiles, encodable,
                                   encode_leaf, normalize_codec)

#: bounded copy granularity of the raw path — large enough to amortize
#: call overhead, small enough that a torn source is caught within one
#: chunk and peak extra memory stays bounded
DEFAULT_CHUNK_BYTES = 8 << 20


class SupersededError(IOError):
    """A queued transfer found its source already overwritten by a newer
    version (e.g. checkpoint slot reuse outpacing a drain). Benign: the
    newer object's own transfer covers it. Collected, never fatal."""


_SHADOW_SEQ = itertools.count()


def _shadow_name(data_name: str) -> str:
    """Unique landing name for a data-region write. Writers NEVER
    ``create`` over the real data name: creating a region truncates the
    backing file, and a reader or rival writer still holding the old
    mapping would take a SIGBUS on its next access. Instead every
    writer streams into its own shadow file and installs it with one
    atomic ``pool.rename`` — old mappings keep their own (consistent)
    inode, and the manifest commit that follows the rename is the only
    thing that makes the new bytes reachable."""
    return f"{data_name}.shadow{next(_SHADOW_SEQ)}"


def _check_expect_meta(man: dict, expect_meta: Optional[dict],
                       verb: str, obj_name: str) -> None:
    """Pin the object identity a queued transfer was meant for: raise
    SupersededError when the snapshotted meta no longer matches (the
    source was rewritten between submit and run)."""
    if not expect_meta:
        return
    got = man.get("meta", {})
    stale = {k: got.get(k) for k in expect_meta
             if got.get(k) != expect_meta[k]}
    if stale:
        raise SupersededError(
            f"{verb} {obj_name}: source changed before {verb} ran "
            f"(wanted {expect_meta}, found {stale})")


def _flatten(tree, prefix="") -> List[Tuple[str, np.ndarray]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _flatten(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten(v, f"{prefix}{i}/")
    elif tree is None:
        pass
    else:
        out.append((prefix[:-1], np.asarray(tree)))
    return out


def content_digest(manifest: dict) -> str:
    """Content digest of an object from its manifest alone: the CRC32 of
    the sorted per-leaf ``path:crc`` pairs. Identical trees produce
    identical digests without re-reading a byte of data — the dataset
    exchange stamps this into lineage records so derived datasets can be
    audited against their recorded inputs. Codec-encoded replicas keep
    the original leaf CRCs in ``leaves`` (encoded CRCs live in
    ``meta["wire_codec"]``), so the digest is stable across encodings."""
    acc = 0
    for path in sorted(manifest.get("leaves", {})):
        ent = manifest["leaves"][path]
        acc = zlib.crc32(f"{path}:{ent['crc']}".encode(), acc)
    return f"{acc & 0xFFFFFFFF:08x}"


def _unflatten(leaves: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for path, v in leaves.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _crc(buf) -> int:
    return zlib.crc32(buf) & 0xFFFFFFFF


def _wc_of(man: dict) -> Optional[dict]:
    return man.get("meta", {}).get("wire_codec")


def _physical_segments(man: dict) -> Tuple[List[Tuple[int, int, int]], int]:
    """The physical byte ranges backing an object as
    ``([(offset, nbytes, crc), ...], region_size)``: the manifest leaf
    table for a plain object, the encoded segment table for a
    codec-encoded one. The raw copy path streams exactly these ranges
    and verifies exactly these CRCs — nothing is decoded or recomputed,
    so a second-hop copy of an encoded replica never double-encodes."""
    wc = _wc_of(man)
    if not wc:
        return ([(e["offset"], e["nbytes"], e["crc"])
                 for e in man["leaves"].values()],
                int(man.get("nbytes", 0)))
    segs = []
    for path, ce in wc["leaves"].items():
        if ce["mode"] == "delta8":
            segs.append((ce["offset"], ce["q_nbytes"], ce["q_crc"]))
            segs.append((ce["scales_offset"], ce["scales_nbytes"],
                         ce["scales_crc"]))
        else:
            segs.append((ce["offset"], ce["nbytes"],
                         man["leaves"][path]["crc"]))
    return segs, int(wc["nbytes_encoded"])


def _materialize_leaf(region, man: dict, path: str, ent: dict,
                      verify: bool) -> np.ndarray:
    """Read ONE leaf into an owned array (never a live memmap view),
    decoding transparently when the object is codec-encoded. The CRC is
    computed over the owned snapshot — exactly the bytes returned — so
    a concurrent overwrite between verify and return is impossible, and
    only one allocation is made per leaf (the snapshot itself)."""
    shape, dtype = tuple(ent["shape"]), np.dtype(ent["dtype"])
    wc = _wc_of(man)
    ce = wc["leaves"].get(path) if wc else None
    if ce is not None and ce["mode"] == "delta8":
        q = np.array(region.read(ce["offset"], ce["q_nbytes"]), copy=True)
        sc = np.array(region.read(ce["scales_offset"],
                                  ce["scales_nbytes"]), copy=True)
        if q.nbytes != ce["q_nbytes"] or sc.nbytes != ce["scales_nbytes"]:
            raise IOError(f"short encoded read for {man['name']}:{path}")
        if verify and (_crc(q) != ce["q_crc"] or
                       _crc(sc) != ce["scales_crc"]):
            raise IOError(
                f"encoded crc mismatch for {man['name']}:{path}")
        raw = decode_leaf(q, sc, ce["tiles"], dtype, ent["nbytes"])
        if verify and wc.get("strict", True) and _crc(raw) != ent["crc"]:
            raise IOError(f"crc mismatch for {man['name']}:{path}")
        return raw.view(dtype).reshape(shape)
    off = ce["offset"] if ce is not None else ent["offset"]
    raw = np.array(region.read(off, ent["nbytes"]), copy=True)
    if raw.nbytes != ent["nbytes"]:
        raise IOError(f"short read for {man['name']}:{path}")
    if verify and _crc(raw) != ent["crc"]:
        raise IOError(f"crc mismatch for {man['name']}:{path}")
    return raw.view(dtype).reshape(shape)


class PMemObjectStore:
    """One node's object store."""

    def __init__(self, pool: PMemPool):
        self.pool = pool

    # ---- write path ----
    def put(self, name: str, tree, version: int = 0,
            meta: Optional[dict] = None) -> dict:
        leaves = _flatten(tree)
        region_name = f"objects/{name}@v{version}.data"
        total = sum(a.nbytes for _, a in leaves)
        shadow = _shadow_name(region_name)
        region = self.pool.create(shadow, max(total, 1))
        manifest = {"name": name, "version": version, "ts": time.time(),
                    "meta": meta or {}, "leaves": {}, "nbytes": total}
        off = 0
        for path, arr in leaves:
            region.write(off, arr)
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "offset": off, "nbytes": arr.nbytes,
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes())
                & 0xFFFFFFFF,
            }
            off += arr.nbytes
        region.flush()  # CLWB+SFENCE before the commit point
        # install the flushed shadow under the real data name (atomic;
        # a concurrent reader's old mapping stays valid on its inode)
        self.pool.rename(shadow, region_name)
        # commit point: manifest rename is atomic
        self.pool.put_json(f"objects/{name}@v{version}.manifest", manifest)
        return manifest

    # ---- read path ----
    def manifest(self, name: str, version: int = 0) -> dict:
        return self.pool.get_json(f"objects/{name}@v{version}.manifest")

    def exists(self, name: str, version: int = 0) -> bool:
        return self.pool.exists(f"objects/{name}@v{version}.manifest")

    def get(self, name: str, version: int = 0, verify: bool = False):
        tree, _ = self.get_with_manifest(name, version, verify=verify)
        return tree

    def get_with_manifest(self, name: str, version: int = 0,
                          verify: bool = True):
        """Read (tree, manifest) against ONE manifest snapshot, CRC-
        verifying every leaf against it. A concurrent overwrite (e.g.
        checkpoint slot reuse racing a queued replicate) produces bytes
        that do not match this manifest's CRCs and raises IOError instead
        of returning torn or wrongly-tagged data. Codec-encoded objects
        (``meta["wire_codec"]``) decode transparently."""
        man = self.manifest(name, version)
        region = self.pool.open(f"objects/{name}@v{version}.data")
        leaves = {}
        for path, ent in man["leaves"].items():
            leaves[path] = _materialize_leaf(region, man, path, ent,
                                             verify)
        return _unflatten(leaves), man

    def get_leaf(self, name: str, leaf: str, version: int = 0,
                 verify: bool = True,
                 man: Optional[dict] = None) -> np.ndarray:
        """Byte-range read of ONE leaf without touching its siblings —
        the partial-restore primitive. Pass ``man`` to amortize the
        manifest read over many leaves of one object. The returned
        array owns its bytes (safe across region close/resize/slot
        reuse) and decodes transparently from encoded replicas."""
        if man is None:
            man = self.manifest(name, version)
        region = self.pool.open(f"objects/{name}@v{version}.data")
        return _materialize_leaf(region, man, leaf, man["leaves"][leaf],
                                 verify)

    def read_leaf_slice(self, name: str, leaf: str, start_row: int,
                        n_rows: int, version: int = 0) -> np.ndarray:
        """Byte-range read of rows [start_row, start_row+n_rows) of a leaf —
        the elastic-reshard primitive (no full-object deserialization).
        Returns an OWNED copy, never a live memmap view: a caller holding
        the result across region close/resize/slot-reuse must not observe
        remapped or torn bytes. On a codec-encoded object only the tiles
        covering the requested rows are read and decoded."""
        man = self.manifest(name, version)
        ent = man["leaves"][leaf]
        shape = tuple(ent["shape"])
        dtype = np.dtype(ent["dtype"])
        row_elems = 1
        for d in shape[1:]:
            row_elems *= d
        row_bytes = dtype.itemsize * row_elems
        region = self.pool.open(f"objects/{name}@v{version}.data")
        wc = _wc_of(man)
        ce = wc["leaves"].get(leaf) if wc else None
        if ce is not None and ce["mode"] == "delta8":
            tile = wc["tile"]
            e_lo = start_row * row_elems
            e_hi = (start_row + n_rows) * row_elems
            t_lo, t_hi = e_lo // tile, -(-e_hi // tile)
            q = np.array(region.read(ce["offset"] + t_lo * tile,
                                     (t_hi - t_lo) * tile), copy=True)
            sc = np.array(region.read(ce["scales_offset"] + t_lo * 4,
                                      (t_hi - t_lo) * 4), copy=True)
            dec = decode_leaf_tiles(q, sc, t_lo, t_hi, dtype)
            out = dec[e_lo - t_lo * tile:
                      e_lo - t_lo * tile + n_rows * row_elems]
            return out.reshape((n_rows,) + shape[1:]).copy()
        off = (ce["offset"] if ce is not None else ent["offset"]) \
            + start_row * row_bytes
        raw = np.array(region.read(off, n_rows * row_bytes), copy=True)
        return raw.view(dtype).reshape((n_rows,) + shape[1:])

    def nbytes_of(self, name: str, version: int = 0) -> int:
        """Object size from the manifest alone (no data reads) — feeds
        byte-weighted workflow placement."""
        return int(self.manifest(name, version).get("nbytes", 0))

    def delete(self, name: str, version: int = 0) -> None:
        self.pool.delete(f"objects/{name}@v{version}.manifest")
        self.pool.delete(f"objects/{name}@v{version}.data")

    def list_objects(self) -> List[Tuple[str, int]]:
        out = []
        for f in self.pool.list("objects/"):
            if f.endswith(".manifest"):
                base = f[len("objects/"):-len(".manifest")]
                name, _, v = base.rpartition("@v")
                out.append((name, int(v)))
        return sorted(out)


# ---- zero-copy byte-range transfer primitives ------------------------

def _obs_instruments(obs):
    if obs is None:
        return None, None, None
    reg = obs.registry
    return (reg.counter("tiered.bytes_raw"),
            reg.counter("tiered.bytes_encoded"),
            reg.histogram("copy.chunk"))


def _write_seg(region, off: int, buf: np.ndarray, chunk_bytes: int,
               hist) -> int:
    """Write one segment in bounded chunks, flushing each chunk before
    the next (and therefore before any later commit point)."""
    pos = 0
    n = buf.nbytes
    while pos < n:
        step = min(chunk_bytes, n - pos)
        region.write(off + pos, buf[pos:pos + step])
        region.flush()
        if hist is not None:
            hist.observe(step)
        pos += step
    return off + n


@rehydration_entry
def copy_object(src: PMemObjectStore, dst: PMemObjectStore, name: str,
                version: int = 0, *, dst_name: Optional[str] = None,
                dst_version: Optional[int] = None,
                meta_update: Union[dict, Callable, None] = None,
                expect_meta: Optional[dict] = None,
                chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                codec=None, verify: bool = True, obs=None) -> dict:
    """The pmem -> pmem raw path: stream the backing region of
    ``name@version`` from ``src`` to ``dst`` in bounded chunks and
    commit the *source manifest verbatim* (new name/version/meta, same
    leaf table, same CRCs). No tree is materialized, no CRC recomputed:
    a rolling CRC over the streamed chunks is checked against the
    manifest's own segment CRCs, and the source manifest is recheck-read
    just before the commit, so a source overwritten mid-copy (slot
    reuse) raises :class:`SupersededError` instead of committing a
    stale replica. Every chunk is flushed before the manifest
    ``put_json``, so
    a crash at any point leaves an uncommitted (invisible) region —
    never a committed manifest over unflushed bytes.

    ``meta_update`` merges extra keys into the copied meta (a callable
    receives the source meta — e.g. to preserve ``replica_of`` origin).
    ``codec`` (spec dict or ``True``) engages the delta-int8 wire codec
    at the source; an already-encoded source is raw-streamed as-is
    (never double-encoded). Source-side failures (gone/torn/short)
    raise SupersededError; destination-side failures propagate."""
    dst_name = dst_name or name
    dst_version = version if dst_version is None else dst_version
    codec = normalize_codec(codec)
    try:
        man = src.manifest(name, version)
        src_region = src.pool.open(f"objects/{name}@v{version}.data")
    except (OSError, ValueError, KeyError) as e:
        raise SupersededError(
            f"copy {name}: source gone before copy ran ({e})") from e
    _check_expect_meta(man, expect_meta, "copy", name)
    raw_ctr, enc_ctr, hist = _obs_instruments(obs)
    data_dst = f"objects/{dst_name}@v{dst_version}.data"
    encode = codec is not None and _wc_of(man) is None and any(
        encodable(e["dtype"], e["nbytes"]) for e in man["leaves"].values())
    shadow = _shadow_name(data_dst)
    try:
        if encode:
            wc_new, phys = _copy_encoded(src_region, man, dst.pool,
                                         shadow, codec, chunk_bytes, hist)
        else:
            wc_new = None
            phys = _copy_raw(src_region, man, dst.pool, shadow,
                             chunk_bytes, hist, verify)
        # freshness recheck while the bytes are still in the shadow:
        # shadow-rename writers hand a concurrent reader a consistent
        # OLD mapping instead of torn bytes, so a source slot reused
        # mid-copy streams cleanly and passes its own (old) manifest
        # CRCs — this recheck is what keeps the superseded snapshot
        # from being committed (and acked) over a fresher replica.
        try:
            cur = src.manifest(name, version)
        except (OSError, ValueError, KeyError) as e:
            raise SupersededError(
                f"copy {name}: source manifest gone at commit "
                f"({e})") from e
        if (cur.get("ts"), cur.get("content_digest")) != \
                (man.get("ts"), man.get("content_digest")):
            raise SupersededError(
                f"copy {name}: source superseded mid-copy (manifest "
                f"changed before commit)")
    except BaseException:
        # every chunk is flushed as it lands, so dropping the
        # uncommitted shadow is clean — no manifest ever pointed at it
        dst.pool.delete(shadow)
        raise
    if raw_ctr is not None:
        raw_ctr.inc(int(man.get("nbytes", 0)))
        if encode or _wc_of(man) is not None:
            enc_ctr.inc(phys)
    meta = dict(man.get("meta", {}))
    if callable(meta_update):
        meta.update(meta_update(man.get("meta", {})) or {})
    elif meta_update:
        meta.update(meta_update)
    if wc_new is not None:
        meta["wire_codec"] = wc_new
    new_man = {**man, "name": dst_name, "version": dst_version,
               "ts": time.time(), "meta": meta}
    # install + commit: all chunk flushes above precede the data
    # rename, and the manifest rename (put_json) is the only thing
    # that makes the new bytes reachable
    dst.pool.rename(shadow, data_dst)
    dst.pool.put_json(f"objects/{dst_name}@v{dst_version}.manifest",
                      new_man)
    return new_man


def _copy_raw(src_region, man: dict, dst_pool: PMemPool, shadow: str,
              chunk_bytes: int, hist, verify: bool) -> int:
    """Stream the manifest's physical segments into the shadow region
    in bounded chunks. The caller owns commit sequencing (freshness
    recheck, rename, manifest put) and shadow cleanup on raise."""
    segs, phys = _physical_segments(man)
    dst_region = dst_pool.create(shadow, max(phys, 1))
    for off, nbytes, want in segs:
        acc = 0
        pos, end = off, off + nbytes
        while pos < end:
            n = min(chunk_bytes, end - pos)
            try:
                buf = src_region.read(pos, n)
            except (OSError, ValueError, AttributeError) as e:
                raise SupersededError(
                    f"copy {man['name']}: source read failed at "
                    f"{pos} ({e})") from e
            if buf.nbytes != n:
                raise SupersededError(
                    f"copy {man['name']}: short source read at "
                    f"{pos} (source resized mid-copy)")
            acc = zlib.crc32(buf, acc)
            dst_region.write(pos, buf)
            dst_region.flush()
            if hist is not None:
                hist.observe(n)
            pos += n
        if verify and nbytes and (acc & 0xFFFFFFFF) != want:
            raise SupersededError(
                f"copy {man['name']}: source bytes diverged from "
                f"manifest crc at offset {off} (source rewritten "
                f"mid-copy)")
    dst_region.flush()
    return phys


def _copy_encoded(src_region, man: dict, dst_pool: PMemPool,
                  shadow: str, codec: dict, chunk_bytes: int,
                  hist) -> Tuple[dict, int]:
    """Encode-at-source variant of the copy loop: each leaf is
    snapshotted once, CRC-checked against the manifest, encoded (or
    passed through raw when not exactly invertible in strict mode) and
    packed sequentially into the shadow region. The caller owns commit
    sequencing and shadow cleanup on raise."""
    tile, strict = codec["tile"], bool(codec.get("strict", True))
    bound = 0
    for e in man["leaves"].values():
        it = np.dtype(e["dtype"]).itemsize
        n = e["nbytes"] // max(it, 1)
        t = -(-n // tile) if n else 0
        bound += max(e["nbytes"], t * tile) + 4 * t
    dst_region = dst_pool.create(shadow, max(bound, 1))
    wc_leaves: Dict[str, dict] = {}
    off = 0
    for path, ent in man["leaves"].items():
            try:
                view = src_region.read(ent["offset"], ent["nbytes"])
            except (OSError, ValueError, AttributeError) as e:
                raise SupersededError(
                    f"copy {man['name']}: source read failed for "
                    f"{path} ({e})") from e
            # one owned snapshot per leaf: CRC, encode and write all
            # see the same bytes even if the source is overwritten now
            raw = np.array(view, copy=True)
            if raw.nbytes != ent["nbytes"]:
                raise SupersededError(
                    f"copy {man['name']}: short source read for {path}")
            if ent["nbytes"] and _crc(raw) != ent["crc"]:
                raise SupersededError(
                    f"copy {man['name']}: source bytes diverged from "
                    f"manifest crc for {path} (rewritten mid-copy)")
            enc = encode_leaf(raw, ent["dtype"], strict=strict)
            if enc is None:
                wc_leaves[path] = {"mode": "raw", "offset": off,
                                   "nbytes": ent["nbytes"]}
                off = _write_seg(dst_region, off, raw, chunk_bytes, hist)
            else:
                q, scales, tiles = enc
                qb = q.view(np.uint8).reshape(-1)
                sb = scales.view(np.uint8).reshape(-1)
                ce = {"mode": "delta8", "tiles": tiles, "offset": off,
                      "q_nbytes": qb.nbytes, "q_crc": _crc(qb)}
                off = _write_seg(dst_region, off, qb, chunk_bytes, hist)
                ce.update({"scales_offset": off,
                           "scales_nbytes": sb.nbytes,
                           "scales_crc": _crc(sb)})
                off = _write_seg(dst_region, off, sb, chunk_bytes, hist)
                wc_leaves[path] = ce
    dst_region.flush()
    dst_region.resize(max(off, 1))  # shrink to the packed size
    return codec_meta(codec, wc_leaves, off), off


def _read_seg(region, off: int, nbytes: int, want_crc: int, man: dict,
              path: str) -> bytes:
    try:
        data = region.read(off, nbytes).tobytes()
    except (OSError, ValueError) as e:
        raise SupersededError(
            f"export {man['name']}: source read failed for {path} "
            f"({e})") from e
    if len(data) != nbytes:
        raise SupersededError(
            f"export {man['name']}: short source read for {path}")
    if nbytes and _crc(data) != want_crc:
        raise SupersededError(
            f"export {man['name']}: source bytes diverged from manifest "
            f"crc for {path} (rewritten mid-export)")
    return data


@rehydration_entry
def export_object(store: PMemObjectStore, name: str, version: int = 0, *,
                  expect_meta: Optional[dict] = None, codec=None,
                  obs=None) -> dict:
    """Read an object ONCE into a self-describing wire payload for the
    external (drain) boundary: ``{"__wire_object__": 1, "manifest",
    "codec", "leaves"}`` with per-leaf raw bytes or encoded (q, scales)
    segments. The caller's external store serializes it exactly once —
    no tree is built, leaf bytes are verified against the manifest CRCs
    as they stream out. An already-encoded source ships its encoded
    segments verbatim."""
    codec = normalize_codec(codec)
    try:
        man = store.manifest(name, version)
        region = store.pool.open(f"objects/{name}@v{version}.data")
    except (OSError, ValueError, KeyError) as e:
        raise SupersededError(
            f"export {name}: source gone before export ran ({e})") from e
    _check_expect_meta(man, expect_meta, "export", name)
    raw_ctr, enc_ctr, _hist = _obs_instruments(obs)
    wc = _wc_of(man)
    leaves: Dict[str, dict] = {}
    spec = None
    enc_bytes = 0
    if wc:
        spec = {"name": wc["name"], "tile": wc["tile"],
                "strict": wc.get("strict", True)}
        for path, ce in wc["leaves"].items():
            if ce["mode"] == "delta8":
                q = _read_seg(region, ce["offset"], ce["q_nbytes"],
                              ce["q_crc"], man, path)
                sc = _read_seg(region, ce["scales_offset"],
                               ce["scales_nbytes"], ce["scales_crc"],
                               man, path)
                leaves[path] = {"mode": "delta8", "tiles": ce["tiles"],
                                "q": q, "scales": sc,
                                "q_crc": ce["q_crc"],
                                "scales_crc": ce["scales_crc"]}
                enc_bytes += len(q) + len(sc)
            else:
                data = _read_seg(region, ce["offset"], ce["nbytes"],
                                 man["leaves"][path]["crc"], man, path)
                leaves[path] = {"mode": "raw", "data": data}
                enc_bytes += len(data)
    else:
        strict = bool(codec.get("strict", True)) if codec else True
        for path, ent in man["leaves"].items():
            data = _read_seg(region, ent["offset"], ent["nbytes"],
                             ent["crc"], man, path)
            enc = encode_leaf(np.frombuffer(data, np.uint8),
                              ent["dtype"], strict=strict) \
                if codec else None
            if enc is None:
                leaves[path] = {"mode": "raw", "data": data}
                enc_bytes += len(data)
            else:
                q, scales, tiles = enc
                qb, sb = q.tobytes(), scales.tobytes()
                leaves[path] = {"mode": "delta8", "tiles": tiles,
                                "q": qb, "scales": sb,
                                "q_crc": _crc(qb),
                                "scales_crc": _crc(sb)}
                enc_bytes += len(qb) + len(sb)
        if codec:
            spec = {"name": codec["name"], "tile": codec["tile"],
                    "strict": strict}
    if raw_ctr is not None:
        raw_ctr.inc(int(man.get("nbytes", 0)))
        if spec is not None:
            enc_ctr.inc(enc_bytes)
    # the shipped manifest carries no wire_codec: the sink's import
    # re-packs the segments and records its own physical layout
    m = dict(man)
    mm = dict(man.get("meta", {}))
    mm.pop("wire_codec", None)
    m["meta"] = mm
    return {"__wire_object__": 1, "manifest": m, "codec": spec,
            "leaves": leaves}


def is_wire_object(obj) -> bool:
    return isinstance(obj, dict) and obj.get("__wire_object__") == 1


@rehydration_entry
def import_object(store: PMemObjectStore, wire: dict,
                  name: Optional[str] = None,
                  version: Optional[int] = None,
                  meta_update: Optional[dict] = None,
                  chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> dict:
    """Wire payload -> pmem (stage-in / rehydration): write the carried
    leaf bytes at manifest offsets (chunked, each chunk flushed before
    the manifest commit) and commit the carried manifest verbatim
    (plus ``meta_update``). Encoded payloads are stored encoded — the
    physical layout is recorded in ``meta["wire_codec"]`` and readers
    decode on demand. Corrupt wire bytes (CRC mismatch vs the carried
    manifest) raise IOError: unlike a racing source overwrite, a torn
    external blob is a real failure, not a benign supersede."""
    man = wire["manifest"]
    name = name or man["name"]
    version = man["version"] if version is None else version
    data_name = f"objects/{name}@v{version}.data"
    spec = wire.get("codec")
    encoded = spec is not None and any(
        l["mode"] == "delta8" for l in wire["leaves"].values())
    wc = None
    shadow = _shadow_name(data_name)
    try:
        if encoded:
            phys = sum(len(l["data"]) if l["mode"] == "raw"
                       else len(l["q"]) + len(l["scales"])
                       for l in wire["leaves"].values())
            region = store.pool.create(shadow, max(phys, 1))
            wc_leaves: Dict[str, dict] = {}
            off = 0
            for path in man["leaves"]:
                l = wire["leaves"][path]
                if l["mode"] == "raw":
                    data = np.frombuffer(l["data"], np.uint8)
                    if data.nbytes and _crc(data) != \
                            man["leaves"][path]["crc"]:
                        raise IOError(
                            f"import {name}: wire bytes corrupt for "
                            f"{path}")
                    wc_leaves[path] = {"mode": "raw", "offset": off,
                                       "nbytes": data.nbytes}
                    off = _write_seg(region, off, data, chunk_bytes,
                                     None)
                else:
                    q = np.frombuffer(l["q"], np.uint8)
                    sc = np.frombuffer(l["scales"], np.uint8)
                    if _crc(q) != l["q_crc"] or \
                            _crc(sc) != l["scales_crc"]:
                        raise IOError(
                            f"import {name}: wire bytes corrupt for "
                            f"{path}")
                    ce = {"mode": "delta8", "tiles": l["tiles"],
                          "offset": off, "q_nbytes": q.nbytes,
                          "q_crc": l["q_crc"]}
                    off = _write_seg(region, off, q, chunk_bytes, None)
                    ce.update({"scales_offset": off,
                               "scales_nbytes": sc.nbytes,
                               "scales_crc": l["scales_crc"]})
                    off = _write_seg(region, off, sc, chunk_bytes, None)
                    wc_leaves[path] = ce
            region.flush()
            wc = codec_meta(spec, wc_leaves, off)
        else:
            region = store.pool.create(shadow,
                                       max(int(man.get("nbytes", 0)), 1))
            for path, ent in man["leaves"].items():
                data = np.frombuffer(wire["leaves"][path]["data"],
                                     np.uint8)
                if data.nbytes and _crc(data) != ent["crc"]:
                    raise IOError(
                        f"import {name}: wire bytes corrupt for {path}")
                _write_seg(region, ent["offset"], data, chunk_bytes,
                           None)
            region.flush()
    except BaseException:
        # torn wire blob: drop the flushed, uncommitted shadow — a
        # previously committed version of this object stays intact
        store.pool.delete(shadow)
        raise
    store.pool.rename(shadow, data_name)
    meta = dict(man.get("meta", {}))
    meta.pop("wire_codec", None)
    if wc is not None:
        meta["wire_codec"] = wc
    if meta_update:
        meta.update(meta_update)
    new_man = {**man, "name": name, "version": version,
               "ts": time.time(), "meta": meta}
    store.pool.put_json(f"objects/{name}@v{version}.manifest", new_man)
    return new_man


def wire_leaves(wire: dict, verify: bool = True) -> Dict[str, np.ndarray]:
    """Decode a wire payload to its flat ``{path: array}`` leaves
    without writing to any pool — the external-tier read used by
    restore's drain fallback."""
    man = wire["manifest"]
    spec = wire.get("codec")
    strict = bool(spec.get("strict", True)) if spec else True
    out: Dict[str, np.ndarray] = {}
    for path, ent in man["leaves"].items():
        l = wire["leaves"][path]
        dtype = np.dtype(ent["dtype"])
        shape = tuple(ent["shape"])
        if l["mode"] == "delta8":
            q = np.frombuffer(l["q"], np.uint8)
            sc = np.frombuffer(l["scales"], np.uint8)
            if verify and (_crc(q) != l["q_crc"] or
                           _crc(sc) != l["scales_crc"]):
                raise IOError(f"wire crc mismatch for {path}")
            raw = decode_leaf(q, sc, l["tiles"], dtype, ent["nbytes"])
            if verify and strict and _crc(raw) != ent["crc"]:
                raise IOError(f"wire crc mismatch for {path}")
            out[path] = raw.view(dtype).reshape(shape)
        else:
            raw = np.frombuffer(l["data"], np.uint8)
            if verify and raw.nbytes and _crc(raw) != ent["crc"]:
                raise IOError(f"wire crc mismatch for {path}")
            out[path] = raw.view(dtype).reshape(shape).copy()
    return out


def wire_tree(wire: dict, verify: bool = True):
    """A wire payload as the pytree it carries (external-boundary
    convenience; the pmem ingest path is :func:`import_object`)."""
    return _unflatten(wire_leaves(wire, verify=verify))


def as_tree(obj):
    """Normalize an external-store blob to the pytree it carries:
    zero-copy drains land as wire payloads (decoded, CRC-verified),
    legacy pickled trees pass through. The helper external-boundary
    consumers (analysis jobs reading drained reports) should use."""
    return wire_tree(obj) if is_wire_object(obj) else obj


class DistributedStore:
    """Union view over per-node stores (the distributed B-APM filesystem)."""

    def __init__(self, stores: Dict[str, PMemObjectStore]):
        self.stores = stores

    def locate(self, name: str, version: int = 0) -> List[str]:
        return [nid for nid, st in self.stores.items()
                if st.exists(name, version)]

    def get(self, name: str, version: int = 0, prefer: Optional[str] = None):
        nodes = self.locate(name, version)
        if not nodes:
            raise KeyError(f"{name}@v{version} not on any node")
        nid = prefer if prefer in nodes else nodes[0]
        return self.stores[nid].get(name, version)

    def nbytes_of(self, name: str, version: int = 0) -> int:
        """Size of an object wherever it lives (0 when nowhere): the
        byte-weighted placement input for raw (non-catalog) objects."""
        for nid in self.locate(name, version):
            try:
                return self.stores[nid].nbytes_of(name, version)
            except (IOError, FileNotFoundError):
                continue
        return 0
