# The paper's primary contribution — the B-APM systemware stack:
# pmem pools (PMDK-style), versioned object store, async data scheduler,
# distributed node-local checkpointing, SLM/DLM tiering, workflow-aware
# scheduling, and failure/straggler resilience. See DESIGN.md §2-§3.
from repro.core.checkpoint import DistributedCheckpointer
from repro.core.cluster import SimCluster
from repro.core.data_scheduler import DataScheduler, ExternalStore
from repro.core.object_store import DistributedStore, PMemObjectStore
from repro.core.pmem import PMemPool, PMemRegion
from repro.core.resilience import (FailureRecovery, Heartbeat,
                                   StragglerDetector)
from repro.core.tiered_io import RepairDaemon, SaveTicket, TieredIO
from repro.core.tiering import DLMCache, SLMTier, TieredKVCache
from repro.core.workflow import JobSpec, WorkflowScheduler
