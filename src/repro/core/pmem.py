"""Byte-addressable persistent memory emulation (PMDK-style pools).

The paper's B-APM hardware is exposed to applications exactly the way PMDK
does it: named pools are mmap'd into the address space and accessed by
byte-granular loads/stores, with explicit flush (CLWB) + fence (SFENCE) for
persistence ordering. On this CPU container a pool region is an
``np.memmap`` over a file in the node's pmem directory — the same mmap
mechanism PMDK uses — and ``flush()`` is ``mmap.flush`` (msync). On a real
TPU host the identical API fronts /dev/dax or an NVMe-backed mount.

One ``PMemPool`` == one node's B-APM. Multi-node topologies are emulated by
one pool directory per node (core/cluster.py).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
import warnings
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


def scratch_root(prefix: str = "repro_pmem_") -> Path:
    """A fresh scratch directory for pmem-pool emulation, preferring
    DRAM-backed tmpfs (/dev/shm). B-APM latencies sit next to DRAM's;
    on a container whose default tmp lives on a slow 9p/overlay disk,
    per-commit fsyncs would otherwise cost ~10ms each and dominate any
    benchmark of the pmem data plane."""
    base = Path("/dev/shm")
    if base.is_dir() and os.access(base, os.W_OK):
        return Path(tempfile.mkdtemp(prefix=prefix, dir=str(base)))
    return Path(tempfile.mkdtemp(prefix=prefix))


class PMemRegion:
    """A named byte range inside a pool, accessed via numpy memmap."""

    def __init__(self, path: Path, nbytes: int, create: bool):
        self.path = path
        self.nbytes = nbytes
        mode = "w+" if create else "r+"
        self._mm = np.memmap(path, dtype=np.uint8, mode=mode, shape=(nbytes,))
        self._flushed = not create

    # ---- byte-addressable access ----
    def write(self, offset: int, data: np.ndarray) -> None:
        buf = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        self._mm[offset:offset + buf.nbytes] = buf
        self._flushed = False

    def read(self, offset: int, nbytes: int, dtype=np.uint8,
             shape=None) -> np.ndarray:
        raw = self._mm[offset:offset + nbytes]
        out = raw.view(dtype)
        return out.reshape(shape) if shape is not None else out

    @property
    def dirty(self) -> bool:
        """True while stores issued since the last ``flush()`` may still
        be sitting in the (emulated) CPU caches — i.e. bytes that a
        crash right now is allowed to lose."""
        return not self._flushed

    def flush(self) -> None:
        """CLWB+SFENCE analogue: force bytes to the persistent medium."""
        self._mm.flush()
        self._flushed = True

    def resize(self, nbytes: int) -> None:
        """Grow (or shrink) the region in place, preserving content up
        to ``min(old, new)`` bytes — the pool-extend primitive behind
        append-only logs. Flushes, remaps; existing offsets stay valid."""
        if nbytes == self.nbytes:
            return
        self._mm.flush()
        del self._mm
        with open(self.path, "r+b") as f:
            f.truncate(nbytes)
        self.nbytes = nbytes
        self._mm = np.memmap(self.path, dtype=np.uint8, mode="r+",
                             shape=(nbytes,))

    def close(self) -> None:
        if self.dirty:
            self.flush()
        del self._mm


class PMemPool:
    """A node's B-APM: a directory of named regions + usage accounting."""

    def __init__(self, root: Path, node_id: str = "node0",
                 capacity_bytes: int = 1 << 34):
        self.root = Path(root) / node_id
        self.node_id = node_id
        self.capacity_bytes = capacity_bytes
        self.root.mkdir(parents=True, exist_ok=True)
        self._root_norm = os.path.normpath(str(self.root))
        self._open: Dict[str, PMemRegion] = {}
        self._lock = threading.RLock()
        self._dead = False
        # put_json commits whose parent-directory fsync the filesystem
        # refused: the rename itself still happened, but its durability
        # is at the mercy of the journal. Counted (and warned once) so
        # a degraded mount is visible instead of silently best-effort.
        self.dir_fsync_failures = 0
        self._dir_fsync_warned = False

    @property
    def alive(self) -> bool:
        return not self._dead

    def fail(self) -> None:
        """Simulate the node's B-APM becoming unreachable (node death).
        Subsequent accesses raise IOError instead of racing with cleanup;
        in-flight async writers fail fast rather than resurrecting
        directories mid-teardown."""
        self._dead = True

    def _check_alive(self) -> None:
        if self._dead:
            raise IOError(f"pmem pool {self.node_id} unreachable")

    def _path(self, name: str) -> Path:
        # lexical containment check (normpath collapses any ".."): a
        # resolve() here costs a realpath syscall chain per metadata
        # access, which dominates small-object traffic on slow mounts
        p = os.path.normpath(os.path.join(self._root_norm, name))
        assert p.startswith(self._root_norm + os.sep), name
        return Path(p)

    def create(self, name: str, nbytes: int) -> PMemRegion:
        with self._lock:
            self._check_alive()
            if self.used_bytes() + nbytes > self.capacity_bytes:
                raise MemoryError(
                    f"pmem pool {self.node_id} over capacity: "
                    f"{self.used_bytes() + nbytes} > {self.capacity_bytes}")
            path = self._path(name)
            path.parent.mkdir(parents=True, exist_ok=True)
            region = PMemRegion(path, nbytes, create=True)
            self._open[name] = region
            return region

    def open(self, name: str) -> PMemRegion:
        with self._lock:
            self._check_alive()
            if name in self._open:
                return self._open[name]
            path = self._path(name)
            region = PMemRegion(path, path.stat().st_size, create=False)
            self._open[name] = region
            return region

    def open_or_create(self, name: str, nbytes: int) -> PMemRegion:
        """Open an existing region, or create it at ``nbytes`` — the
        idempotent entry point for append-only logs."""
        with self._lock:
            self._check_alive()
            if self.exists(name):
                return self.open(name)
            return self.create(name, nbytes)

    def extend(self, name: str, nbytes: int) -> PMemRegion:
        """Grow a region to at least ``nbytes`` (byte-range log growth —
        no whole-file rewrite). Returns the (possibly resized) region."""
        with self._lock:
            self._check_alive()
            region = self.open(name)
            if region.nbytes < nbytes:
                grow = nbytes - region.nbytes
                if self.used_bytes() + grow > self.capacity_bytes:
                    raise MemoryError(
                        f"pmem pool {self.node_id} over capacity: "
                        f"{self.used_bytes() + grow} > "
                        f"{self.capacity_bytes}")
                region.resize(nbytes)
            return region

    def rename(self, src: str, dst: str) -> None:
        """Atomically replace region ``dst`` with ``src`` (POSIX rename)
        — the commit point of log compaction and of every shadow-region
        data install: the new file becomes the name in one step, so a
        crash leaves either the old bytes or the new ones, never a torn
        mix. Open handles to both names are flushed (if dirty) and
        evicted from the cache — a re-``open`` maps the new file — but
        NOT unmapped: a concurrent reader still holding the old ``dst``
        region object keeps its own mapping of the replaced inode,
        which stays fully consistent (just superseded) instead of
        faulting mid-read. Copy writers recheck source-manifest
        freshness at their commit point for exactly this reason
        (object_store.copy_object)."""
        with self._lock:
            self._check_alive()
            for name in (src, dst):
                r = self._open.pop(name, None)
                if r is not None and r.dirty:
                    r.flush()
            os.replace(self._path(src), self._path(dst))

    def exists(self, name: str) -> bool:
        return not self._dead and self._path(name).exists()

    def delete(self, name: str) -> None:
        # same eviction discipline as rename: flush a dirty handle but
        # leave the mapping alive for any reader mid-stream on it
        with self._lock:
            r = self._open.pop(name, None)
            if r is not None and r.dirty:
                r.flush()
            p = self._path(name)
            if p.exists():
                p.unlink()

    def list(self, prefix: str = "") -> Iterator[str]:
        if self._dead:
            return
        # walk only the directory component of the prefix — a catalog
        # listing of exch/<wf>/ must not stat every checkpoint slot
        base = self.root
        dir_part = prefix.rpartition("/")[0]
        if dir_part:
            base = self.root / dir_part
            if not base.is_dir():
                return
        names = []
        for dirpath, _dirs, files in os.walk(base):
            rel_dir = os.path.relpath(dirpath, self.root)
            for f in files:
                rel = f if rel_dir == "." else f"{rel_dir}/{f}"
                if rel.startswith(prefix):
                    names.append(rel)
        yield from sorted(names)

    def used_bytes(self) -> int:
        total = 0
        for dirpath, _dirs, files in os.walk(self.root):
            for f in files:
                try:
                    total += os.stat(os.path.join(dirpath, f)).st_size
                except OSError:
                    continue  # e.g. a .tmp committed (renamed) mid-scan
        return total

    # ---- small atomic metadata (manifests) ----
    def put_json(self, name: str, obj) -> None:
        """Crash-consistent metadata commit: tmp write + fsync + rename
        + parent-dir fsync. A crash at ANY point leaves either the old
        complete record or the new complete record — never torn bytes —
        so the cross-pool merge readers can treat every readable copy as
        well-formed (and tolerate the unreadable ones)."""
        self._check_alive()
        path = self._path(name)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        with open(tmp, "w") as f:
            json.dump(obj, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic on POSIX
        # persist the rename itself: without the directory fsync the
        # rename can be reordered past the crash and resurrect the tmp
        try:
            dfd = os.open(path.parent, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            # some filesystems refuse directory fsync; the commit is
            # still atomic (rename happened), only its durability
            # ordering is weakened — account for it instead of hiding it
            self.dir_fsync_failures += 1
            if not self._dir_fsync_warned:
                self._dir_fsync_warned = True
                warnings.warn(
                    f"pmem pool {self.node_id}: parent-directory fsync "
                    f"failed for {name!r}; metadata commits on this "
                    f"mount are rename-atomic but not "
                    f"durability-ordered (counted in "
                    f"dir_fsync_failures)", RuntimeWarning)

    def get_json(self, name: str):
        self._check_alive()
        with open(self._path(name)) as f:
            return json.load(f)
