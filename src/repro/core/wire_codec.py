"""Delta-int8 wire codec: the ``kernels/ckpt_codec`` pallas codec as an
opt-in compression stage on the copy channels (replicate / drain /
rehydrate) — trading flops for fabric bytes (ROADMAP item 4).

Encoding happens at the *source* of a copy, decoding on demand at the
sink: an encoded replica is stored encoded and only decoded when a
reader actually asks for leaf bytes (``get_with_manifest`` /
``get_leaf`` / ``read_leaf_slice`` decode transparently). The codec
parameters and the CRCs of the *encoded* segments ride in the object
manifest under ``meta["wire_codec"]``, so acks, repair scans and
re-replication of an encoded object stay metadata-only — and a second
hop (repair copying a replica off a surviving holder) raw-streams the
already-encoded bytes instead of double-encoding.

Lossless by construction: in the default ``strict`` mode every leaf is
encoded and immediately decoded back at the source; a leaf whose
round-trip is not bit-identical falls back to raw passthrough (mode
``"raw"`` in the codec leaf table). Strict mode snaps each tile's
scale to the next power of two above ``absmax/127`` — a pow2 scale is
exactly representable and ``q * scale`` multiplies exactly in f32, so
any tile whose values sit on an <= 8-bit integer grid (small-int
embedding tables, quantized weights, the integer step counters of an
optimizer tree) reproduces bit-for-bit at ~1/4 the fabric bytes for
f32, while arbitrary float noise ships raw and loses nothing. The wire
format (int8 q tiles + f32 per-tile scales) and the decode path are
exactly the ``ckpt_codec`` kernel's; ``strict=False`` instead encodes
with the kernel's own ``absmax/127`` scale and shares the delta-
checkpoint chain's lossy semantics — readers then skip the
original-CRC check and verify the encoded CRCs instead.

The transfer base is zeros (self-delta): both ends of a copy channel
always share it, so no base-resolution handshake is needed — the true
inter-step delta chain stays the checkpointer's job.
"""
from __future__ import annotations

import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.kernels.ckpt_codec.ref import TILE, decode_ref, encode_ref

#: codec spec used when a caller opts in with ``wire_codec=True``
DEFAULT_CODEC = {"name": "delta8", "tile": TILE, "strict": True}

#: dtypes worth quantizing (ints/bools ship raw: int8 deltas of int
#: payloads would only inflate them with scale rows)
_FLOAT_KINDS = ("f",)


def normalize_codec(codec) -> Optional[dict]:
    """``None``/falsy -> None, ``True`` -> DEFAULT_CODEC, dict -> the
    dict with defaults filled in."""
    if not codec:
        return None
    if codec is True:
        return dict(DEFAULT_CODEC)
    out = dict(DEFAULT_CODEC)
    out.update(codec)
    return out


def encodable(dtype: np.dtype, nbytes: int) -> bool:
    """Only float leaves with at least one full tile's worth of elements
    are candidates — tiny leaves pay more in scale rows + metadata than
    quantization saves."""
    dtype = np.dtype(dtype)
    return dtype.kind in _FLOAT_KINDS and \
        nbytes >= TILE * dtype.itemsize


def _encode_pow2(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Strict-mode quantizer: the kernel's tile/q/scale wire format,
    but with each tile's scale snapped UP to the nearest power of two
    >= absmax/127. A pow2 scale is exactly representable in f32 and
    ``q * scale`` multiplies exactly, so values on an <= 8-bit integer
    grid (times any pow2) decode bit-identically via the unmodified
    ``decode_ref``/pallas decode kernel."""
    absmax = np.max(np.abs(x), axis=-1, keepdims=True).astype(np.float64)
    with np.errstate(divide="ignore"):
        exp = np.ceil(np.log2(absmax / 127.0))
    scale = np.where(absmax > 0, np.exp2(exp), 1.0).astype(np.float32)
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def encode_leaf(buf: np.ndarray, dtype: np.dtype,
                strict: bool = True
                ) -> Optional[Tuple[np.ndarray, np.ndarray, int]]:
    """Encode one leaf's raw bytes (uint8 view/copy) into
    ``(q[int8, tiles*TILE], scales[f32, tiles], tiles)`` against a zero
    base. Returns None when the leaf should ship raw: non-float dtype,
    sub-tile size, or (strict mode) a round-trip that is not
    bit-identical to the source bytes."""
    dtype = np.dtype(dtype)
    if not encodable(dtype, buf.nbytes):
        return None
    flat = np.asarray(buf).view(dtype).reshape(-1)
    n = flat.size
    tiles = -(-n // TILE)
    x = np.zeros((tiles, TILE), np.float32)
    x.reshape(-1)[:n] = flat.astype(np.float32, copy=False)
    if strict:
        q, scale = _encode_pow2(x)
        dec = decode_ref(q, scale, 0.0, dtype=dtype).reshape(-1)[:n]
        if dec.tobytes() != flat.tobytes():
            return None  # not exactly invertible -> raw passthrough
    else:
        q, scale = encode_ref(x, 0.0)
    return q.reshape(-1), scale.reshape(-1), tiles


def decode_leaf(q: np.ndarray, scales: np.ndarray, tiles: int,
                dtype: np.dtype, nbytes: int) -> np.ndarray:
    """Inverse of :func:`encode_leaf`: raw uint8 leaf bytes from the
    encoded segments (drops the zero padding of the last tile)."""
    dtype = np.dtype(dtype)
    n = nbytes // dtype.itemsize
    qm = np.asarray(q).view(np.int8).reshape(tiles, TILE)
    sm = np.asarray(scales).view(np.float32).reshape(tiles, 1)
    dec = decode_ref(qm, sm, 0.0, dtype=dtype).reshape(-1)[:n]
    return dec.view(np.uint8).reshape(-1)


def decode_leaf_tiles(q: np.ndarray, scales: np.ndarray, tile_lo: int,
                      tile_hi: int, dtype: np.dtype) -> np.ndarray:
    """Decode only tiles [tile_lo, tile_hi) of a leaf — the byte-range
    primitive under ``read_leaf_slice`` on encoded objects. ``q`` and
    ``scales`` are the raw segment bytes for exactly that tile range."""
    dtype = np.dtype(dtype)
    tiles = tile_hi - tile_lo
    qm = np.asarray(q).view(np.int8).reshape(tiles, TILE)
    sm = np.asarray(scales).view(np.float32).reshape(tiles, 1)
    return decode_ref(qm, sm, 0.0, dtype=dtype).reshape(-1)


def crc(buf) -> int:
    return zlib.crc32(buf) & 0xFFFFFFFF


def codec_meta(spec: dict, leaves: Dict[str, dict],
               nbytes_encoded: int) -> dict:
    """The ``meta["wire_codec"]`` record: codec params + the physical
    (encoded) segment table with encoded CRCs — everything a repair
    scan or a second-hop copy needs without touching payload bytes."""
    return {"name": spec["name"], "tile": spec["tile"],
            "strict": bool(spec.get("strict", True)),
            "leaves": leaves, "nbytes_encoded": int(nbytes_encoded)}
